"""End-to-end serving driver (the paper's kind of system is SEARCH, so the
end-to-end example serves a small model with batched requests):

  1. train a reduced two-tower retrieval model for a few hundred steps,
  2. embed an item corpus with the trained item tower,
  3. build the supermetric (BSS) index over the corpus,
  4. serve batched top-k requests EXACTLY, measuring pruning.

    PYTHONPATH=src python examples/retrieval_serving.py [--steps 200]
"""

import argparse

import jax
import numpy as np

from repro.configs import common
from repro.configs.registry import get_arch
from repro.core.npdist import pairwise_np
from repro.data.pipeline import ClickStream
from repro.optim import adamw
from repro.serve.queue import now
from repro.serve.retrieval import RetrievalServer
from repro.train.loop import TrainLoop, TrainLoopConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--corpus", type=int, default=20_000)
    ap.add_argument("--queries", type=int, default=128)
    ap.add_argument("--k", type=int, default=10)
    args = ap.parse_args()

    # 1. train
    bundle = get_arch("two-tower-retrieval")
    model, cfg, _ = bundle.make_reduced()
    loop = TrainLoop(
        common.loss_for("recsys", model), adamw(lr=3e-3),
        ClickStream(model.cfg, batch=64, seed=0),
        TrainLoopConfig(total_steps=args.steps, checkpoint_every=10**9,
                        checkpoint_dir="/tmp/repro_tt_ckpt", log_every=50),
    )
    state = loop.init_or_restore(lambda: model.init_params(jax.random.PRNGKey(0)))
    state = loop.run(state)
    print(f"trained {args.steps} steps: loss {loop.losses[0]:.3f} -> "
          f"{loop.losses[-1]:.3f}")

    # 2./3. embed + index
    params = state["params"]
    rng = np.random.default_rng(1)
    item_ids = rng.integers(0, model.cfg.vocab,
                            size=(args.corpus, model.cfg.n_item_fields))
    user_ids = rng.integers(0, model.cfg.vocab,
                            size=(args.queries, model.cfg.n_user_fields))
    corpus = np.asarray(model.item_embed(params, item_ids))
    users = np.asarray(model.user_embed(params, user_ids))
    server = RetrievalServer(corpus)
    print(f"indexed {args.corpus} items in {server.index.n_blocks} blocks")

    # 4. serve
    t0 = now()
    top = server.top_k(users, args.k)
    dt = now() - t0

    # verify exactness on a subsample
    d = pairwise_np("l2", users[:16] / np.linalg.norm(users[:16], axis=1,
                                                      keepdims=True),
                    server.corpus)
    hit = sum(
        len(set(np.argsort(d[i])[: args.k]) & set(np.asarray(top[i]).tolist()))
        for i in range(16)
    )
    print(f"top-{args.k} x {args.queries} queries in {dt:.2f}s "
          f"({dt / args.queries * 1e3:.1f} ms/query, fused batched engine)")
    print(f"recall@{args.k} (exactness check) = {hit / (16 * args.k):.3f}")
    s = server.stats
    print(f"distances/query = {s.dists_per_query:.0f} / {args.corpus} "
          f"({100 * s.saving:.1f}% pruned by the four-point lower bound)")


if __name__ == "__main__":
    main()
